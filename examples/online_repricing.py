"""Online cost-grid repricing: the autoscaler consults fresh grids per tick.

The PR-10 incremental suite machinery makes `serve_cost_grids` cheap enough
to call INSIDE a fleet control loop. This demo runs a diurnal 24-tick
scenario where the per-token KV footprint drifts tick to tick (longer
contexts through the evening peak — exactly the situation where yesterday's
cost grid misprices today's step times):

1. every tick reprices the (batch x KV-bucket) grids for both configs with
   that tick's ``kv_bytes_per_token`` — the changed KV byte counts APPEND
   rows to the process-wide KV-sweep session suite (O(new trace), capacity
   union inherited) instead of keying a cold suite per tick;
2. the queue-depth autoscaler (``repro.ft.elastic.QueueDepthAutoscaler``)
   then resizes the fleet from the repriced grid: offered load over the
   repriced saturation ceiling gives the backlog observation it reacts to;
3. the per-tick wall cost of repricing is printed — the first tick pays the
   one-time session build, every later tick reprices in milliseconds.

    PYTHONPATH=src python examples/online_repricing.py [--ticks 24]
"""
import argparse
import math
import sys
import time

sys.path.insert(0, "src")

from repro.core import copa
from repro.core.cachesim import stream_cache_stats
from repro.core.sweep import serve_cost_grids
import repro.core.sweep as sweep_mod
from repro.ft.elastic import QueueDepthAutoscaler

BASE_KV_PER_TOKEN = 8 * 1024 * 2 * 4       # gnmt decoder KV proxy (bytes)
CONFIGS = [copa.GPU_N_BASE, copa.HBM_L3]
OUT_TOKENS = 48                            # mean decode length per request


def offered_rps(tick: int, ticks: int) -> float:
    """Diurnal offered load: trough 60k req/s, peak 220k req/s (a
    datacenter-scale gnmt fleet — one instance saturates at ~7-10k)."""
    phase = 2.0 * math.pi * tick / ticks
    return 140e3 + 80e3 * math.sin(phase - math.pi / 2)


def kv_bytes_per_token(tick: int, ticks: int) -> float:
    """Context-length drift: up to +60% KV per token through the peak."""
    phase = 2.0 * math.pi * tick / ticks
    return BASE_KV_PER_TOKEN * (1.0 + 0.6 * max(0.0, math.sin(phase)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=24)
    args = ap.parse_args()

    scaler = QueueDepthAutoscaler(max_instances=256)
    n, peak_n = 1, 1
    print(f"{'tick':>4s} {'rps':>6s} {'kv/tok':>8s} {'reprice':>9s} "
          f"{'rps/inst':>8s} {'fleet':>5s}  session")
    for tick in range(args.ticks):
        rps = offered_rps(tick, args.ticks)
        kvpt = kv_bytes_per_token(tick, args.ticks)

        t0 = time.perf_counter()
        grids = serve_cost_grids("gnmt", CONFIGS, tokens_per_pass=50,
                                 kv_bytes_per_token=kvpt)
        reprice_ms = (time.perf_counter() - t0) * 1e3

        grid = grids["GPU-N"]
        # Repriced saturation ceiling -> the backlog observation the
        # autoscaler reacts to: requests the current fleet cannot absorb
        # appear as queued batches; a draining fleet reports its running
        # occupancy. One tick spans several autoscale intervals, each
        # consulting the SAME repriced grid.
        per_inst = grid.saturated_rps(OUT_TOKENS)
        for _ in range(8):
            backlog = max(rps - n * per_inst, 0.0) * 8.0
            running = min(rps / per_inst, float(n)) * grid.max_batch
            n = scaler.decide(n, int(backlog), int(running), grid.max_batch)
        peak_n = max(peak_n, n)

        session = sweep_mod._KV_SUITE.n_traces if sweep_mod._KV_SUITE else 0
        print(f"{tick:>4d} {rps:>6.1f} {kvpt/1024:>7.1f}K {reprice_ms:>7.2f}ms "
              f"{per_inst:>8.2f} {n:>5d}  {session} kv rows")

    stats = stream_cache_stats()
    print(f"\nstream cache after {args.ticks} ticks: "
          f"{stats['hits']} hits / {stats['misses']} misses / "
          f"{stats['evictions']} evictions, "
          f"{stats['entries']} entries ({stats['bytes'] / 1e6:.1f} MB)")
    ideal = math.ceil(max(offered_rps(t, args.ticks)
                          for t in range(args.ticks)) / per_inst)
    print(f"peak-load ideal fleet ~{ideal} instances; "
          f"autoscaler peaked at {peak_n}, ended at {n}")


if __name__ == "__main__":
    main()
