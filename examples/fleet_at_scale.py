"""Fleet sizing at datacenter scale: the vectorized fleet core end to end.

Sizes a 200+-instance serving fleet for a bursty, mixed-rate request
stream against a latency SLO, using real COPA cost grids (converged GPU-N
vs DL-COPA MSMs from the sweep engine's cost-grid export). The workflow:

1. price the per-step costs once per config (``serve_cost_grids``);
2. replay ONE 20k-request bursty arrival trace through fleets of
   increasing size via :func:`scan_fleet` — the bisection schedule probes
   O(log N) sizes, and each probe runs the batched engine
   (``repro.serve.fleetbatch``), which prices a 200-instance x 20k-request
   fleet in well under a second;
3. print the probed ladder per config plus the smallest SLO-meeting size;
4. re-run the winning GPU-N fleet with the obs column on and drop its
   Chrome-trace timeline (``fleet_at_scale_timeline.json`` — open in
   chrome://tracing or https://ui.perfetto.dev) plus a windowed metric
   table showing the burst cycles beating against the SLO.

The batched engine is bit-identical to the per-instance reference loop
(``FleetSim.run(..., batched=False)`` — asserted in
tests/test_fleet_batch.py), so the answer is exactly what the slow loop
would give, ~10x sooner.

    PYTHONPATH=src python examples/fleet_at_scale.py [--requests 20000]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.core import copa
from repro.core.sweep import serve_cost_grids
from repro.obs.timeline import write_chrome_trace
from repro.serve.fleet import FleetSim, scan_fleet
from repro.serve.sim import ArrivalSpec, LengthDist, ObsConfig, Slo

KV_BYTES_PER_TOKEN = 8 * 1024 * 2 * 4      # gnmt decoder KV proxy

CONFIGS = [copa.GPU_N_BASE, copa.HBM_L3]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=20_000)
    ap.add_argument("--max-instances", type=int, default=320)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default="fleet_at_scale_timeline.json",
                    help="Chrome-trace timeline of the sized GPU-N fleet "
                         "('' to skip)")
    args = ap.parse_args()

    grids = serve_cost_grids(
        "gnmt", CONFIGS, tokens_per_pass=50,
        kv_bytes_per_token=KV_BYTES_PER_TOKEN,
    )
    base = grids["GPU-N"]
    out_mean = 48
    # offered load sized so the GPU-N answer lands above 200 instances,
    # with diurnal-style bursts: 25% of each period at 3x the trough rate
    rate = 320 * 0.8 * base.saturated_rps(out_mean)
    # burst period scaled to the trace so several on/off cycles land
    # inside it regardless of --requests
    period = args.requests / rate / 5.0
    arrivals = ArrivalSpec(
        name="example.mixed", rate=rate, n_requests=args.requests,
        burst_factor=3.0, burst_fraction=0.25, period_s=period,
        prompt=LengthDist("fixed", mean=12, floor=1),
        output=LengthDist("lognormal", mean=out_mean, sigma=0.4, floor=4),
    )
    slo = Slo(ttft_s=10 * base.step_time(1), tpot_s=5 * base.step_time(1),
              percentile=95)
    print(f"offered: {rate:.0f} r/s bursty (peak {2 * rate:.0f}), "
          f"{args.requests} requests; SLO: p{slo.percentile:.0f} "
          f"TTFT<={slo.ttft_s * 1e3:.0f}ms TPOT<={slo.tpot_s * 1e3:.1f}ms")

    sized = {}
    for name, grid in grids.items():
        t0 = time.perf_counter()
        scanned = scan_fleet(grid, arrivals, slo,
                             max_instances=args.max_instances,
                             seed=args.seed, strategy="bisect")
        dt = time.perf_counter() - t0
        met = [n for n, m in scanned.items() if slo.met(m)]
        ladder = " ".join(
            f"{n}{'*' if slo.met(m) else ''}"
            for n, m in sorted(scanned.items()))
        answer = f"{min(met)} instances" if met \
            else f">{args.max_instances} (cap)"
        print(f"{name:<12} probed [{ladder}] -> {answer} "
              f"({len(scanned)} probes, {dt:.1f}s)")
        if met:
            sized[name] = min(met)

    if args.trace_out and "GPU-N" in sized:
        # one more batched run of the answer-sized fleet, obs column on,
        # and the whole run becomes a browsable timeline + metric table
        n = sized["GPU-N"]
        res = FleetSim(grids["GPU-N"], n,
                       obs=ObsConfig(level=1)).run(arrivals, seed=args.seed)
        doc = write_chrome_trace(args.trace_out, res, max_requests=2_000)
        series = res.timeseries(res.metrics.makespan_s / 12, slo=slo)
        print(f"\ntimeline of the {n}-instance GPU-N fleet -> "
              f"{args.trace_out} ({len(doc['traceEvents'])} events; "
              f"chrome://tracing)")
        print(series.table())


if __name__ == "__main__":
    main()
