"""Quickstart: train a ~100M-param model for a few hundred steps on CPU.

    PYTHONPATH=src python examples/quickstart.py [--steps 300]

Uses the full public API: config registry -> LanguageModel -> sharded train
step -> deterministic data pipeline -> watchdog -> async checkpoints.
The model is whisper-base's decoder-family cousin at ~100M params — big
enough to be real, small enough for a CPU box.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import repro.configs as configs
from repro.launch.train import main as train_main


def build_100m():
    """A ~100M dense config registered on the fly."""
    base = configs.get("tinyllama-1.1b")
    cfg = dataclasses.replace(
        base, name="quickstart-100m", n_layers=6, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=1536, vocab_size=8192)
    configs.ARCHS[cfg.name] = cfg
    print(f"quickstart model: {cfg.n_params()/1e6:.1f}M params")
    return cfg


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/quickstart_ckpt")
    args = ap.parse_args()
    build_100m()
    train_main(["--arch", "quickstart-100m", "--steps", str(args.steps),
                "--global-batch", "8", "--seq-len", "256",
                "--ckpt-dir", args.ckpt_dir, "--save-every", "100",
                "--log-every", "10"])
