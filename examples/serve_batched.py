"""Serving under load: the request-level simulator on COPA configs.

Replays Poisson arrivals at a few offered rates through one simulated
serving instance per config (converged GPU-N vs DL-COPA MSMs) and prints
the latency-percentile + SLO-goodput table — the fleet-level view of the
paper's serving claim. The per-token step costs come straight from the
sweep engine's cost-grid export over the ``serve.mlperf.gnmt.b*`` scenarios
(gnmt's 50-step decoder priced per output token, KV residency bucketed so a
cache that fits the COPA L3 is swept at UHB bandwidth).

    PYTHONPATH=src python examples/serve_batched.py [--requests 400]

The jax model-serving driver (real prefill/decode on a toy arch) remains at
``python -m repro.launch.serve``; ``--sim`` there runs this same analytic
path for one config.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import copa
from repro.core.sweep import serve_cost_grids
from repro.serve.fleet import latency_goodput_rows
from repro.serve.sim import ArrivalSpec, LengthDist, Slo

# gnmt decoder KV proxy: 8 layers x 1024 hidden x K+V x fp32.
KV_BYTES_PER_TOKEN = 8 * 1024 * 2 * 4

CONFIGS = [copa.GPU_N_BASE, copa.HBM_L3, copa.HBML_L3L]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # Prefill priced per config from a real prefill-chunk trace (the
    # lm.*.prefill_* cells) instead of a flat s/token knob.
    grids = serve_cost_grids(
        "gnmt", CONFIGS, tokens_per_pass=50,
        kv_bytes_per_token=KV_BYTES_PER_TOKEN,
        prefill_scenario="lm.tinyllama-1.1b.prefill_32k",
    )
    base = grids["GPU-N"]
    out_mean = 48
    sat = base.saturated_rps(out_mean)   # GPU-N full-batch ceiling
    rates = [round(f * sat, 1) for f in (0.5, 0.8, 1.1)]
    arrivals = ArrivalSpec(
        name="example.poisson", rate=sat, n_requests=args.requests,
        prompt=LengthDist("fixed", mean=12, floor=1),
        output=LengthDist("lognormal", mean=out_mean, sigma=0.4, floor=4),
    )
    slo = Slo(ttft_s=4 * base.step_time(1), tpot_s=2 * base.step_time(1),
              percentile=95)

    rows = latency_goodput_rows(grids, arrivals, rates, slo, seed=args.seed)
    hdr = (f"{'config':<12} {'rate r/s':>9} {'TTFT p50':>9} {'TTFT p99':>9} "
           f"{'TPOT p99':>9} {'goodput':>8} {'SLO':>4}")
    print(f"one instance per config; SLO: p{slo.percentile:.0f} "
          f"TTFT<={slo.ttft_s*1e3:.1f}ms TPOT<={slo.tpot_s*1e3:.1f}ms")
    print(hdr)
    for r in rows:
        print(f"{r['config']:<12} {r['rate_rps']:>9.1f} "
              f"{r['ttft_p50_ms']:>7.2f}ms {r['ttft_p99_ms']:>7.2f}ms "
              f"{r['tpot_p99_ms']:>7.2f}ms {r['goodput_rps']:>8.1f} "
              f"{'ok' if r['slo_met'] else 'MISS':>4}")
    return rows


if __name__ == "__main__":
    main()
