"""Batched serving: prefill a batch of prompts, decode with the KV cache.

    PYTHONPATH=src python examples/serve_batched.py --arch yi-6b-smoke
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--batch", str(args.batch),
                "--prompt-len", "16", "--gen", str(args.gen),
                "--max-len", "64"])
